"""Multi-rail SOR refactor coverage (core/sor.py, docs/sor.md):

  * independence — the per-rail fits are elementwise over the rail axis:
    perturbing one rail's samples never moves another rail's frontier;
  * kernel — `ops.sor_accumulate` / the Pallas `sor_accumulate` body match
    the pure-jnp EWLS sums to f32 tolerance under jit and vmap;
  * the PR-4 pin — a 1-rail (VDD_IO-only) config reproduces the
    pre-refactor scalar learner's fit bit-exactly (and the cold-start
    static pin is covered by tests/test_sor.py);
  * persistence — `SorState` survives ckpt.save -> restore -> `remap_sor`
    across fleet sizes (survivors keep learned regions, joiners cold-start);
  * plumbing — per-rail observables through `poll_frame(grad_error={rail:
    ...})`, the host controller's polled ingest, `MultiRailClosedLoop`, and
    the SOR-threaded fleet train step.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager, remap_sor
from repro.core import sor
from repro.core.control_plane import HostRailController, InGraphRailController
from repro.core.fleet import FleetPowerManager
from repro.core.policy import ClosedLoop, MultiRailClosedLoop
from repro.core.power_plane import PowerPlaneState, StepProfile
from repro.core.telemetry import (ALL_RAIL_OBSERVABLES, FrameHistory,
                                  Provenance, RailObservable, TelemetryFrame)
from repro.kernels import ops, ref

BOUND = 5e-3
RAILS3 = ALL_RAIL_OBSERVABLES   # (VDD_CORE, VDD_HBM, VDD_IO)


def _frames3(n_chips, v_points, onsets, rng=None, drop=()):
    """Synthetic 3-rail stream: every rail at voltage v with its own
    frontier-shaped observable; rails named in `drop` omit their observable
    (that rail's lane records as invalid)."""
    frames = []
    for v in v_points:
        vv = jnp.full((n_chips,), float(v), jnp.float32)

        def obs(rail):
            on = jnp.asarray(onsets[rail], jnp.float32)
            return BOUND * 10.0 ** jnp.clip(30.0 * (on - vv), -6.0, 3.0)

        extras = {}
        if "VDD_CORE" not in drop:
            extras["straggle_rate"] = obs("VDD_CORE")
        if "VDD_HBM" not in drop:
            extras["hbm_error_rate"] = obs("VDD_HBM")
        err = (obs("VDD_IO") if "VDD_IO" not in drop
               else jnp.full((n_chips,), jnp.nan))
        frames.append(TelemetryFrame(
            grad_error=err, v_io=vv, v_core=vv, v_hbm=vv,
            age_s=jnp.zeros((n_chips,)), extras=extras,
            provenance=Provenance.POLLED))
    return frames


# -- independence ---------------------------------------------------------------

def test_multirail_fits_are_independent():
    """Perturbing the VDD_CORE samples must never move the VDD_IO frontier:
    the rail axis is elementwise through history, fit and envelopes."""
    cfg = sor.SorConfig(refresh_every=1, rails=RAILS3, ingest="frames")
    onsets_a = {"VDD_CORE": [0.66, 0.70], "VDD_HBM": [0.90, 0.95],
                "VDD_IO": [0.63, 0.67]}
    # same IO/HBM world, very different CORE onsets
    onsets_b = {**onsets_a, "VDD_CORE": [0.72, 0.75]}

    def learn(onsets):
        st = sor.init_state(cfg, n_chips=2)
        for f in _frames3(2, np.linspace(0.95, 0.60, 24), onsets):
            st = sor.observe(st, f, cfg)
        return st.estimate

    ea, eb = learn(onsets_a), learn(onsets_b)
    i_core = cfg.rail_index("VDD_CORE")
    i_io = cfg.rail_index("VDD_IO")
    i_hbm = cfg.rail_index("VDD_HBM")
    # the CORE frontier moved with its onsets...
    assert not np.allclose(np.asarray(ea.v_frontier[i_core]),
                           np.asarray(eb.v_frontier[i_core]))
    # ...while IO and HBM are bit-identical
    for i in (i_io, i_hbm):
        for field in ("intercept", "slope", "v_frontier", "confidence",
                      "n_eff"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ea, field)[i]),
                np.asarray(getattr(eb, field)[i]), err_msg=field)


def test_rail_without_observable_stays_cold():
    """A rail whose observable the frames never carry records nothing:
    zero confidence (the cold-start static pin), while the other rails
    learn normally."""
    cfg = sor.SorConfig(refresh_every=1, rails=RAILS3, ingest="frames")
    onsets = {"VDD_CORE": [0.66], "VDD_HBM": [0.90], "VDD_IO": [0.64]}
    st = sor.init_state(cfg, n_chips=1)
    for f in _frames3(1, np.linspace(0.95, 0.60, 24), onsets,
                      drop=("VDD_HBM",)):
        st = sor.observe(st, f, cfg)
    conf = np.asarray(st.estimate.confidence)
    assert conf[cfg.rail_index("VDD_HBM")] == 0.0
    assert conf[cfg.rail_index("VDD_CORE")] > 0.5
    assert conf[cfg.rail_index("VDD_IO")] > 0.5
    envs = sor.rail_envelopes(st.estimate, cfg)
    # the cold rail's envelope IS the static one, bit-exactly
    np.testing.assert_array_equal(
        np.asarray(envs["VDD_HBM"].floor(0.90)), np.float32(0.90))


# -- the kernel -----------------------------------------------------------------

@pytest.mark.parametrize("window,n", [(8, 16), (32, 128), (32, 130),
                                      (17, 384)])
def test_sor_accumulate_kernel_matches_reference(window, n):
    """Interpret-mode Pallas accumulation vs the jnp oracle, padded and
    unpadded shapes."""
    from repro.kernels.fleet_telemetry import sor_accumulate
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0.5, 1.0, (window, n)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(window, n)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.0, 1.0, (window, n)), jnp.float32)
    got = sor_accumulate(x, y, w, interpret=True)
    want = ref.sor_accumulate_reference(x, y, w)
    for g, t in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(t),
                                   rtol=1e-5, atol=1e-5)


def test_sor_accumulate_under_jit_and_vmap():
    """The ops dispatch path is jit/vmap-pure and matches the reference to
    f32 tolerance (acceptance criterion)."""
    rng = np.random.default_rng(1)
    xb = jnp.asarray(rng.uniform(0.5, 1.0, (3, 16, 32)), jnp.float32)
    yb = jnp.asarray(rng.normal(size=(3, 16, 32)), jnp.float32)
    wb = jnp.asarray(rng.uniform(0.0, 1.0, (3, 16, 32)), jnp.float32)
    jitted = jax.jit(ops.sor_accumulate)(xb[0], yb[0], wb[0])
    want0 = ref.sor_accumulate_reference(xb[0], yb[0], wb[0])
    for g, t in zip(jitted, want0):
        np.testing.assert_allclose(np.asarray(g), np.asarray(t),
                                   rtol=1e-5, atol=1e-5)
    vmapped = jax.vmap(ops.sor_accumulate)(xb, yb, wb)
    for i in range(3):
        want = ref.sor_accumulate_reference(xb[i], yb[i], wb[i])
        for g, t in zip(vmapped, want):
            np.testing.assert_allclose(np.asarray(g[i]), np.asarray(t),
                                       rtol=1e-5, atol=1e-5)


# -- the PR-4 pin: 1-rail config == the pre-refactor scalar learner -------------

def _pr4_fit(v_io, error, valid, cursor, capacity, cfg):
    """The pre-refactor (PR-4) EWLS fit, verbatim: operates on the flat
    [capacity, n_chips] arrays the old FrameHistory stored."""
    eps = jnp.float32(1e-9)
    slots = jnp.arange(capacity)
    rank = (cursor - 1 - slots) % capacity
    w = jnp.asarray(cfg.decay, jnp.float32) ** rank
    w = w.reshape((capacity,) + (1,) * (v_io.ndim - 1))
    w = w * valid.astype(jnp.float32)
    x = jnp.where(valid, v_io, 0.0)
    y = jnp.clip(jnp.log10(jnp.maximum(error, 10.0 ** sor.LOG10_ERR_FLOOR)),
                 sor.LOG10_ERR_FLOOR, sor.LOG10_ERR_CEIL)
    y = jnp.where(valid, y, 0.0)
    sw = jnp.sum(w, axis=0)
    sx = jnp.sum(w * x, axis=0)
    sy = jnp.sum(w * y, axis=0)
    sxx = jnp.sum(w * x * x, axis=0)
    sxy = jnp.sum(w * x * y, axis=0)
    denom = sw * sxx - sx * sx
    slope = (sw * sxy - sx * sy) / jnp.maximum(denom, eps)
    intercept = (sy - slope * sx) / jnp.maximum(sw, eps)
    var_x = jnp.maximum(sxx / jnp.maximum(sw, eps)
                        - (sx / jnp.maximum(sw, eps)) ** 2, 0.0)
    steep = slope < -jnp.float32(cfg.min_slope)
    spread = var_x > jnp.float32(cfg.min_spread_v) ** 2
    usable = steep & spread & (denom > eps)
    log10_bound = jnp.float32(np.log10(cfg.error_bound))
    v_frontier = jnp.where(
        usable, (log10_bound - intercept) / jnp.where(usable, slope, -1.0),
        0.0)
    v_frontier = jnp.clip(v_frontier, 0.0, 2.0)
    confidence = jnp.where(
        usable, 1.0 - jnp.exp(-sw / jnp.float32(cfg.conf_samples)), 0.0)
    return {
        "intercept": jnp.where(usable, intercept, 0.0).astype(jnp.float32),
        "slope": jnp.where(usable, slope, 0.0).astype(jnp.float32),
        "v_frontier": v_frontier.astype(jnp.float32),
        "confidence": confidence.astype(jnp.float32),
        "n_eff": sw.astype(jnp.float32),
    }


def test_one_rail_fit_bit_identical_to_pr4():
    """Acceptance: with the default 1-rail (VDD_IO-only) config, the
    rail-indexed fit reproduces the PR-4 scalar learner bit-exactly — the
    [n_rails=1] axis and the ops.sor_accumulate routing change nothing."""
    cfg = sor.SorConfig(refresh_every=1, decay=0.96, error_bound=BOUND)
    n = 5
    v_on = jnp.asarray(np.linspace(0.62, 0.70, n), jnp.float32)
    h = FrameHistory.create(cfg.capacity, n_chips=n)
    rng = np.random.default_rng(7)
    for v in np.linspace(0.76, 0.58, 40):   # wraps the ring, mixed validity
        vv = jnp.full((n,), float(v), jnp.float32)
        err = BOUND * 10.0 ** jnp.clip(30.0 * (v_on - vv), -6.0, 3.0)
        if rng.random() < 0.2:              # occasional dead chip 0 lane
            vv = vv.at[0].set(jnp.nan)
        h = h.push(TelemetryFrame(grad_error=err, v_io=vv, v_core=vv,
                                  v_hbm=vv, provenance=Provenance.POLLED))
    est = sor.fit_history(h, cfg)
    want = _pr4_fit(h.v_io, h.error, h.valid[:, 0], h.cursor,
                    cfg.capacity, cfg)
    for field, w in want.items():
        np.testing.assert_array_equal(
            np.asarray(getattr(est, field)[0]), np.asarray(w),
            err_msg=field)
    assert (np.asarray(est.confidence) > 0).any()   # the fit really ran


# -- persistence: checkpoint round-trip + remap across fleet sizes --------------

def _learned_state(cfg, n_chips, onsets=None):
    onsets = onsets or {
        "VDD_CORE": np.linspace(0.62, 0.68, n_chips),
        "VDD_HBM": np.linspace(0.88, 0.93, n_chips),
        "VDD_IO": np.linspace(0.61, 0.67, n_chips)}
    st = sor.init_state(cfg, n_chips)
    for f in _frames3(n_chips, np.linspace(0.95, 0.58, 24), onsets):
        st = sor.observe(st, f, cfg)
    return st


def test_sor_state_checkpoint_roundtrip(tmp_path):
    cfg = sor.SorConfig(refresh_every=1, rails=RAILS3, ingest="frames")
    st = _learned_state(cfg, 4)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, {"sor": st})
    step, restored = mgr.restore({"sor": sor.init_state(cfg, 4)})
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(restored["sor"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # meta (capacity, rails) comes from the template, not the npz
    assert restored["sor"].history.rails == RAILS3


def test_remap_sor_across_fleet_sizes():
    cfg = sor.SorConfig(refresh_every=1, rails=RAILS3, ingest="frames")
    st = _learned_state(cfg, 4)
    grown = remap_sor(st, 6)
    assert grown.history.chip_shape == (6,)
    conf = np.asarray(grown.estimate.confidence)
    # survivors keep their learned regions bit-exactly...
    np.testing.assert_array_equal(conf[:, :4],
                                  np.asarray(st.estimate.confidence))
    # ...joiners start at the cold-start pin (no history, zero confidence)
    assert (conf[:, 4:] == 0).all()
    assert not np.asarray(grown.history.valid)[:, :, 4:].any()
    envs = sor.rail_envelopes(grown.estimate, cfg)
    np.testing.assert_array_equal(
        np.asarray(envs["VDD_IO"].floor(0.65))[4:], np.float32(0.65))
    # shrink keeps the surviving prefix
    shrunk = remap_sor(st, 2)
    np.testing.assert_array_equal(
        np.asarray(shrunk.estimate.v_frontier),
        np.asarray(st.estimate.v_frontier)[:, :2])
    # same size is a no-op; scalar states have nothing to remap
    assert remap_sor(st, 4) is st
    with pytest.raises(ValueError, match="fleet-shaped"):
        remap_sor(sor.init_state(cfg), 4)


def test_restore_rejects_mismatched_rail_layout(tmp_path):
    """A SorState learned under one rails layout must never restore into a
    config with a different layout — the arrays would index one rail's
    learned frontier as another's (safety, not just shape hygiene)."""
    cfg3 = sor.SorConfig(refresh_every=1, rails=RAILS3, ingest="frames")
    st = _learned_state(cfg3, 2)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"sor": st})
    cfg1 = sor.SorConfig()                    # default 1-rail VDD_IO
    with pytest.raises(ValueError, match="rails"):
        mgr.restore({"sor": sor.init_state(cfg1, 2)})
    # same rail NAMES but a different bound is still a layout mismatch (the
    # frontier was cut at the old bound; relabeling it would be silent)
    respec = tuple(dataclasses.replace(s, error_bound=1e-6) for s in RAILS3)
    with pytest.raises(ValueError, match="rails"):
        mgr.restore({"sor": sor.init_state(
            dataclasses.replace(cfg3, rails=respec), 2)})
    # a different window capacity would break the ring arithmetic
    with pytest.raises(ValueError, match="capacity"):
        mgr.restore({"sor": sor.init_state(
            dataclasses.replace(cfg3, capacity=16), 2)})
    # the matching layout still round-trips
    step, restored = mgr.restore({"sor": sor.init_state(cfg3, 2)})
    assert step == 1 and restored["sor"].history.rails == RAILS3


def test_restore_skips_groups_missing_from_checkpoint(tmp_path):
    """A pre-SOR checkpoint restores into a SOR-enabled state template when
    the caller marks the group optional (the trainer does); a missing
    REQUIRED group still raises loudly instead of silently restarting that
    state from fresh."""
    cfg = sor.SorConfig(rails=RAILS3, ingest="frames")
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"plane": PowerPlaneState.fleet(2)})
    fresh = sor.init_state(cfg, 2)
    template = {"plane": PowerPlaneState.fleet(2), "sor": fresh}
    step, restored = mgr.restore(template, optional=("sor",))
    assert step == 1 and "sor" not in restored and "plane" in restored
    with pytest.raises(KeyError, match="sor"):
        mgr.restore(template)   # not marked optional -> loud


# -- per-rail observable plumbing -----------------------------------------------

def test_poll_frame_per_rail_dict():
    """poll_frame(grad_error={rail: value}) places each rail's observable
    under its canonical key; missing rails record NaN (invalid sample)."""
    fpm = FleetPowerManager(2)
    f = fpm.poll_frame(grad_error={"VDD_IO": np.full(2, 1e-3),
                                   "VDD_CORE": np.full(2, 2e-3)})
    np.testing.assert_allclose(np.asarray(f.grad_error), 1e-3)
    np.testing.assert_allclose(np.asarray(f.extras["straggle_rate"]), 2e-3)
    assert np.isnan(np.asarray(f.extras["hbm_error_rate"])).all()
    # VDD_IO missing from the dict -> NaN grad_error, not silent attribution
    f2 = fpm.poll_frame(grad_error={"VDD_CORE": np.full(2, 2e-3)})
    assert np.isnan(np.asarray(f2.grad_error)).all()
    with pytest.raises(ValueError, match="unknown rail"):
        fpm.poll_frame(grad_error={"VDD_OOPS": 1.0})
    # legacy scalar spelling unchanged: attributed to grad_error alone
    f3 = fpm.poll_frame(grad_error=np.full(2, 5e-4))
    np.testing.assert_allclose(np.asarray(f3.grad_error), 5e-4)
    assert "straggle_rate" not in f3.extras


def test_host_polled_ingest_multirail():
    """The poll-fed host loop learns each rail from its own observable; a
    rail whose observable the caller never reports stays at the static
    pin instead of inheriting the VDD_IO error."""
    cfg = sor.SorConfig(capacity=24, refresh_every=2, decay=0.96,
                        guard_v=0.004, max_extension_v=0.12, rails=RAILS3)
    hc = HostRailController(
        MultiRailClosedLoop(floors={"VDD_CORE": 0.70, "VDD_HBM": 1.00,
                                    "VDD_IO": 0.70}),
        settle_band_frac=0.001, decide_from="poll", sor=cfg)
    hc.enable_polling(interval_s=1e-3)
    plane = PowerPlaneState.nominal()
    for _ in range(40):
        hc.fleet.idle(5e-3)
        err = BOUND * 10.0 ** jnp.clip(30.0 * (0.78 - plane.v_io), -6.0, 3.0)
        sr = BOUND * 10.0 ** jnp.clip(30.0 * (0.72 - plane.v_core), -6.0, 3.0)
        plane = hc.control_step(
            plane, {"grad_error": err, "straggle_rate": sr})
    s = hc.sor_summary()
    assert s["VDD_IO/chips_learned"] == 1
    assert s["VDD_CORE/chips_learned"] == 1
    assert s["VDD_HBM/chips_learned"] == 0     # never reported -> cold
    assert 0.775 < s["VDD_IO/floor_mean_v"] < 0.80
    assert 0.715 < s["VDD_CORE/floor_mean_v"] < 0.74
    assert float(hc.last_envelope["VDD_HBM"].floor(1.00)) == 1.00


def test_multirail_policy_holds_unobserved_rails():
    """MultiRailClosedLoop walks only rails with observables; NaN or absent
    observables hold that rail in place."""
    pol = MultiRailClosedLoop()
    plane = PowerPlaneState.nominal()
    frame = TelemetryFrame(grad_error=jnp.float32(1e-4), v_io=plane.v_io,
                           v_core=plane.v_core, v_hbm=plane.v_hbm)
    req = pol.decide(plane, frame)
    # IO walks down (observable under bound); CORE/HBM have no observable
    assert float(req.v_io) == pytest.approx(float(plane.v_io) - pol.step_v)
    assert req.v_core is None and req.v_hbm is None
    # NaN observable: the rail holds position instead of walking blind
    f2 = dataclasses.replace(
        frame, extras={"straggle_rate": jnp.float32(np.nan)})
    req2 = pol.decide(plane, f2)
    assert float(req2.v_core) == pytest.approx(float(plane.v_core))
    # over-bound observable backs off toward nominal
    f3 = dataclasses.replace(
        frame, extras={"straggle_rate": jnp.float32(1.0)})
    req3 = pol.decide(plane, f3)
    assert float(req3.v_core) > float(plane.v_core) - 1e-6
    # a floors dict scoped to a subset of rails never walks the others,
    # even when their observable is present in the frame
    scoped = MultiRailClosedLoop(floors={"VDD_IO": 0.75})
    f4 = dataclasses.replace(
        frame, extras={"straggle_rate": jnp.float32(1e-4)})
    req4 = scoped.decide(plane, f4)
    assert req4.v_core is None and req4.v_io is not None
    # NaN grad_error holds the compression level too (never resets to
    # lossless on missing telemetry)
    escalated = dataclasses.replace(plane, comp_level=jnp.int32(2))
    f5 = dataclasses.replace(frame, grad_error=jnp.float32(np.nan))
    assert int(pol.decide(escalated, f5).comp_level) == 2


def test_unknown_age_carries_zero_fit_weight():
    """A sample pushed with the documented NaN staleness sentinel records
    as infinitely stale: zero weight under age_halflife_s (conservative,
    matching StalenessGuard), not the perfectly-fresh 0.0 of a silent
    coercion."""
    cfg = sor.SorConfig(refresh_every=1, age_halflife_s=1.0)
    h = FrameHistory.create(4)
    h = h.push(TelemetryFrame(grad_error=jnp.float32(1e-3),
                              v_io=jnp.float32(0.9),
                              age_s=jnp.float32(np.nan),
                              provenance=Provenance.POLLED))
    assert np.isinf(np.asarray(h.age_s)[0])
    w = np.asarray(h.recency_weights(cfg.decay)
                   * 0.5 ** (np.asarray(h.age_s)[:, None]
                             / cfg.age_halflife_s))
    assert w[0, 0] == 0.0


def test_stale_polled_samples_downweight_an_actual_refit():
    """End-to-end through fit_history, not just the weight vector: a batch
    of RECENTLY-PUSHED but stale-at-observation POLLED samples (big
    `age_s` — a PMBus poll that returned an old READ_VOUT conversion)
    carries a misleading frontier. Staleness-blind weighting hands them
    the highest recency weight and drags the fitted frontier toward their
    onset; with `age_halflife_s` set, the same window refits to
    (essentially) the fresh samples' frontier. And with every age at 0.0
    the halflife multiplies weights by exactly 1.0f, so the config is
    bit-inert on fresh-only telemetry — turning the knob on cannot move
    an all-fresh fleet's envelopes."""
    n = 4
    sweep = np.linspace(0.76, 0.58, 24)

    def _frame(v, onset, age):
        vv = jnp.full((n,), float(v), jnp.float32)
        err = BOUND * 10.0 ** jnp.clip(30.0 * (onset - vv), -6.0, 3.0)
        return TelemetryFrame(grad_error=err, v_io=vv, v_core=vv,
                              v_hbm=vv,
                              age_s=jnp.full((n,), float(age)),
                              provenance=Provenance.POLLED)

    h = FrameHistory.create(40, n_chips=n)
    for v in sweep:                      # fresh world: onset 0.66
        h = h.push(_frame(v, 0.66, 0.0))
    for v in sweep[::3]:                 # stale poll: onset LOOKED like 0.72
        h = h.push(_frame(v, 0.72, 60.0))

    aware = sor.fit_history(
        h, sor.SorConfig(refresh_every=1, decay=0.96, error_bound=BOUND,
                         age_halflife_s=2.0))
    blind = sor.fit_history(
        h, sor.SorConfig(refresh_every=1, decay=0.96, error_bound=BOUND))
    assert (np.asarray(aware.confidence) > 0).all()
    vf_aware = np.asarray(aware.v_frontier)[0]
    vf_blind = np.asarray(blind.v_frontier)[0]
    # 0.5**(60/2) ~ 1e-9: the stale batch is effectively erased, so the
    # aware frontier sits at the fresh onset; the blind one is dragged
    # >= 20 mV up toward the stale batch's 0.72
    assert (vf_aware < vf_blind - 0.02).all()
    np.testing.assert_allclose(vf_aware, 0.66, atol=0.01)

    h_fresh = FrameHistory.create(40, n_chips=n)
    for v in sweep:
        h_fresh = h_fresh.push(_frame(v, 0.66, 0.0))
    on = sor.fit_history(
        h_fresh, sor.SorConfig(refresh_every=1, decay=0.96,
                               error_bound=BOUND, age_halflife_s=2.0))
    off = sor.fit_history(
        h_fresh, sor.SorConfig(refresh_every=1, decay=0.96,
                               error_bound=BOUND))
    for field in ("intercept", "slope", "v_frontier", "confidence",
                  "n_eff"):
        np.testing.assert_array_equal(np.asarray(getattr(on, field)),
                                      np.asarray(getattr(off, field)),
                                      err_msg=field)


def test_host_actuate_only_with_sor_rejected():
    """sor= on a policy-less (pure actuation) host controller would never
    observe anything — reject instead of silently never learning."""
    with pytest.raises(ValueError, match="actuate-only"):
        HostRailController(None, sor=sor.SorConfig())


def test_reduce_worst_ignores_nan_lanes():
    """One unmeasured (NaN) chip must not poison the worst-chip reduction:
    the genuinely over-bound chip still gates the fleet; all-NaN stays NaN
    (nothing measured -> every chip holds)."""
    f = TelemetryFrame(
        grad_error=jnp.asarray([np.nan, 1e-2, 1e-4], jnp.float32),
        extras={"straggle_rate": jnp.asarray([np.nan, np.nan, np.nan],
                                             jnp.float32)})
    r = f.reduce_worst(("grad_error", "straggle_rate"))
    np.testing.assert_allclose(np.asarray(r.grad_error),
                               np.full(3, 1e-2), rtol=1e-6)
    assert np.isnan(np.asarray(r.extras["straggle_rate"])).all()


def test_bare_envelope_never_crosses_rails():
    """A bare SafeEnvelope carries its rail tag: an envelope fitted on
    VDD_CORE is never silently blended into VDD_IO decisions (and the
    untagged historical spelling still means VDD_IO)."""
    core_env = sor.SafeEnvelope(v_min=jnp.float32(0.66),
                                confidence=jnp.float32(1.0),
                                rail="VDD_CORE")
    assert sor.envelope_for(core_env, "VDD_CORE") is core_env
    assert sor.envelope_for(core_env, "VDD_IO") is None
    assert sor.as_envelopes(core_env) == {"VDD_CORE": core_env}
    legacy = sor.SafeEnvelope(v_min=jnp.float32(0.70),
                              confidence=jnp.float32(1.0))
    assert sor.envelope_for(legacy, "VDD_IO") is legacy
    # safe_envelope() on a 1-rail non-IO config tags its rail
    cfg = sor.SorConfig(rails=(sor.DEFAULT_RAIL_OBSERVABLES[0]
                               .__class__("VDD_CORE", "v_core",
                                          "straggle_rate"),),
                        ingest="frames")
    env = sor.safe_envelope(sor.SorEstimate.init(2), cfg)
    assert env.rail == "VDD_CORE"
    assert sor.envelope_for(env, "VDD_IO") is None


# -- the SOR-threaded fleet train step ------------------------------------------

def test_fleet_train_step_threads_sor_state():
    """make_fleet_train_step(fleet_cfg.sor=...) returns the 6-arg step that
    learns in-graph: confidence accrues during training and the per-rail
    envelopes clamp arbitration — all inside one jitted step."""
    from repro.configs import get_config
    from repro.models import registry
    from repro.optim import adamw
    from repro.optim.schedule import wsd
    from repro.train.step import (FleetStepConfig, StepConfig,
                                  jit_train_step, make_fleet_train_step)
    from repro.train.trainer import initial_plane_and_ef
    from repro.data.pipeline import SyntheticLM, DataConfig
    from repro.core.hwspec import FleetSpec

    cfg_m = get_config("minicpm_2b", tiny=True)
    api = registry.build(cfg_m, remat="none")
    params = api.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(grad_clip_norm=1.0)
    opt = adamw.init_state(params, opt_cfg)
    sched = lambda s: wsd(s, peak_lr=1e-3, warmup_steps=2, stable_steps=50,
                          decay_steps=50)
    n = 3
    fs = FleetSpec.sample(n, seed=7)
    scfg = sor.SorConfig(capacity=16, refresh_every=2, ingest="frames",
                         rails=RAILS3)
    fleet_cfg = FleetStepConfig(spec=fs, hbm_error_base=1e-4, sor=scfg)
    profile = StepProfile(flops_per_chip=2e12, hbm_bytes_per_chip=8e9,
                          ici_bytes_per_chip=4e9, grad_bytes_per_chip=3e9)
    step = jit_train_step(
        make_fleet_train_step(lambda p, b: api.loss_fn(p, b), opt_cfg,
                              sched, profile,
                              StepConfig(policy=MultiRailClosedLoop()),
                              fleet_cfg),
        donate=False)
    data = SyntheticLM(DataConfig(vocab_size=cfg_m.vocab_size, seq_len=32,
                                  global_batch=4, seed=0))
    plane, ef = initial_plane_and_ef(params, fleet=fs)
    ss = sor.init_state(scfg, n)
    for i in range(6):
        params, opt, plane, ef, ss, metrics = step(
            params, opt, plane, ef, ss, data.jax_batch(i))
    assert int(ss.tick) == 6
    # the walked rails accrued confidence in-graph (VDD_HBM walks on the
    # margin-coupled injection observable)
    conf = np.asarray(ss.estimate.confidence)
    assert conf.shape == (3, n)
    assert (conf > 0).any()
    assert float(metrics["fleet/sor_conf_mean"]) > 0.0
    # polled ingest is rejected up front for the bus-less in-graph step
    with pytest.raises(ValueError, match="ingest"):
        make_fleet_train_step(
            lambda p, b: api.loss_fn(p, b), opt_cfg, sched, profile,
            StepConfig(policy=MultiRailClosedLoop()),
            dataclasses.replace(fleet_cfg, sor=sor.SorConfig(rails=RAILS3)))
    # and a SOR config with no policy to consume it is an error, not a no-op
    with pytest.raises(ValueError, match="policy"):
        make_fleet_train_step(
            lambda p, b: api.loss_fn(p, b), opt_cfg, sched, profile,
            StepConfig(policy=None), fleet_cfg)
    # a caller-owned controller is never mutated: the step clones it with
    # the SOR config instead of assigning into the user's instance
    mine = InGraphRailController(MultiRailClosedLoop())
    make_fleet_train_step(lambda p, b: api.loss_fn(p, b), opt_cfg, sched,
                          profile, StepConfig(policy=mine), fleet_cfg)
    assert mine.sor is None


def test_sor_rejects_legacy_update_only_policy():
    """A legacy update_*-only policy under sor= would learn envelopes the
    legacy decision path never consumes — both controllers refuse loudly
    instead of silently running static control."""
    from repro.core.policy import Policy

    class Legacy(Policy):
        name = "legacy-only"

        def update_jax(self, state, telemetry):
            return state

    scfg = sor.SorConfig(ingest="frames")
    with pytest.raises(ValueError, match="legacy"):
        InGraphRailController(Legacy(), sor=scfg)
    with pytest.raises(ValueError, match="legacy"):
        HostRailController(Legacy(), sor=sor.SorConfig())
    # decide() policies are accepted as before
    InGraphRailController(ClosedLoop(), sor=scfg)


def test_summary_rejects_mismatched_rail_config():
    """summary() with a config whose rail count disagrees with the estimate
    must refuse instead of folding rails into the chip axis."""
    est = sor.SorEstimate.init(4, n_rails=3)
    with pytest.raises(ValueError, match="rail"):
        sor.summary(est, sor.SorConfig())   # 1-rail default cfg, 3-rail est
