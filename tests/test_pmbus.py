"""PMBus engine tests: wire timing (Fig 4 primitives), UCD9248 device model
(Table I commands, PAGE mechanism), serialized transaction discipline."""

import pytest

from repro.core import codecs
from repro.core.pmbus import (Cmd, PmBus, Primitive, SimClock, Transaction,
                              Ucd9248, build_board, primitive_clocks,
                              transaction_seconds)
from repro.core.rails import KC705_RAIL_MAP


def test_primitive_clock_counts():
    # 9 clocks per byte (8 bits + ACK) + START/STOP framing (paper §IV-A)
    assert primitive_clocks(Primitive.WRITE_BYTE) == 29
    assert primitive_clocks(Primitive.WRITE_WORD) == 38
    assert primitive_clocks(Primitive.READ_BYTE) == 39
    assert primitive_clocks(Primitive.READ_WORD) == 48


def test_transaction_seconds_scales_with_clock():
    t400 = transaction_seconds(Primitive.WRITE_WORD, 400_000)
    t100 = transaction_seconds(Primitive.WRITE_WORD, 100_000)
    assert t100 == pytest.approx(4 * t400)
    assert t400 == pytest.approx(38 / 400_000)


def test_unsupported_clock_rejected():
    with pytest.raises(ValueError):
        transaction_seconds(Primitive.WRITE_WORD, 1_000_000)


@pytest.fixture
def board():
    clock, bus, channels = build_board(KC705_RAIL_MAP)
    return clock, bus, channels


def test_page_selects_rail(board):
    clock, bus, channels = board
    # VCCBRAM: addr 54, PAGE 1 (paper Table II / §IV-E example)
    bus.execute(Transaction(Primitive.WRITE_BYTE, 54, Cmd.PAGE, (1,)))
    word = codecs.linear16_encode(0.9)
    bus.execute(Transaction(Primitive.WRITE_WORD, 54, Cmd.VOUT_COMMAND,
                            codecs.word_to_bytes_le(word)))
    # rail 9 = VCCBRAM should now be slewing toward 0.9
    ch = channels[9]
    assert ch.target_v == pytest.approx(0.9, abs=1e-3)
    # other rails untouched
    assert channels[0].target_v == pytest.approx(1.0)


def test_bad_page_nacks(board):
    _, bus, _ = board
    comp = bus.execute(Transaction(Primitive.WRITE_BYTE, 54, Cmd.PAGE, (7,)))
    assert not comp.ok and comp.nack


def test_address_nack_costs_wire_time(board):
    clock, bus, _ = board
    t0 = clock.now
    comp = bus.execute(Transaction(Primitive.READ_WORD, 99, Cmd.READ_VOUT))
    assert not comp.ok and comp.nack
    assert clock.now > t0


def test_read_vout_linear16(board):
    clock, bus, channels = board
    bus.execute(Transaction(Primitive.WRITE_BYTE, 53, Cmd.PAGE, (2,)))  # MGTAVCC
    comp = bus.execute(Transaction(Primitive.READ_WORD, 53, Cmd.READ_VOUT))
    assert comp.ok
    v = codecs.linear16_decode(codecs.bytes_le_to_word(*comp.data))
    assert v == pytest.approx(1.0, abs=5e-3)  # nominal + ADC noise


def test_serialization_enforced(board):
    clock, bus, _ = board

    class Evil(Ucd9248):
        def handle(self, txn, t_end):
            bus.execute(Transaction(Primitive.READ_WORD, 53, Cmd.READ_VOUT))
            return super().handle(txn, t_end)

    bus.devices[77] = Evil(77, {})
    with pytest.raises(RuntimeError, match="serialization"):
        bus.execute(Transaction(Primitive.WRITE_BYTE, 77, Cmd.PAGE, (0,)))


def test_clear_faults(board):
    clock, bus, channels = board
    ch = channels[6]
    ch.fault_latched = True
    bus.execute(Transaction(Primitive.WRITE_BYTE, 53, Cmd.PAGE, (2,)))
    comp = bus.execute(Transaction(Primitive.SEND_BYTE, 53, Cmd.CLEAR_FAULTS))
    assert comp.ok and not ch.fault_latched
