"""Fleet-native stack tests: FleetSpec determinism and variation-aware
accounting, array-aware TelemetryLog (the scalar-only coercion regression),
scalar-vs-fleet trainer equivalence at n_chips=1, fleet-trainer e2e with
per-chip records and worst-chip gating, READ_VOUT polling back-pressure on
the fleet bus, the sharded worst-chip reduction, and a fleet_frontier smoke
run (the per-PR fleet regression gate)."""

import dataclasses
import math
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.fleet import FleetPowerManager
from repro.core.hwspec import V5E, FleetSpec
from repro.core.policy import BERBounded, ClosedLoop, WorstChipGate
from repro.core.power_plane import (PowerPlaneState, StepProfile, account_step,
                                    account_step_fleet)
from repro.core.telemetry import TelemetryLog
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.kernels import ops
from repro.models import registry
from repro.optim import adamw
from repro.optim.schedule import wsd
from repro.train.step import (FleetStepConfig, StepConfig, jit_train_step,
                              make_fleet_train_step, make_train_step)
from repro.train.trainer import Trainer, TrainerConfig, initial_plane_and_ef

CFG = get_config("minicpm_2b", tiny=True)
PROFILE = StepProfile(flops_per_chip=5e9, hbm_bytes_per_chip=5e8,
                      ici_bytes_per_chip=2e8, grad_bytes_per_chip=1.8e8)


# -- FleetSpec -----------------------------------------------------------------

def test_fleet_spec_deterministic_and_seeded():
    a = FleetSpec.sample(64, seed=5)
    b = FleetSpec.sample(64, seed=5)
    for f in ("v_core_nominal", "v_hbm_nominal", "v_io_nominal",
              "leakage_scale", "error_sensitivity"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    c = FleetSpec.sample(64, seed=6)
    assert not np.array_equal(a.v_core_nominal, c.v_core_nominal)
    assert a.n_chips == 64
    # spread is real but bounded (±3σ truncation keeps chips in-envelope)
    assert np.std(a.v_core_nominal) > 0
    assert np.all(np.abs(a.v_core_nominal / V5E.nominal_v_core - 1) < 0.04)
    assert np.all(a.error_sensitivity >= 1.0)


def test_fleet_spec_uniform_is_zero_spread():
    fs = FleetSpec.uniform(4)
    np.testing.assert_array_equal(
        fs.v_core_nominal, np.full(4, np.float32(V5E.nominal_v_core)))
    np.testing.assert_array_equal(fs.leakage_scale, np.ones(4, np.float32))
    chip = fs.chip(2)
    assert chip.nominal_v_core == pytest.approx(V5E.nominal_v_core)
    assert chip.p_core_static_w == pytest.approx(V5E.p_core_static_w)


def test_fleet_accounting_uses_per_chip_variation():
    fs = FleetSpec.sample(8, seed=9)
    state = PowerPlaneState.from_fleet(fs)
    out, metrics = account_step_fleet(PROFILE, state, fs)
    # batched == per-chip scalar accounting with that chip's variation row
    var = fs.variation()
    for i in range(8):
        row = {k: jnp.asarray(v[i]) for k, v in var.items()}
        chip_out, m = account_step(PROFILE, state.chip(i), fs.base,
                                   variation=row)
        np.testing.assert_allclose(np.asarray(out.energy_j)[i],
                                   float(chip_out.energy_j), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(metrics["power_w"])[i],
                                   float(m["power_w"]), rtol=1e-6)
    # every chip starts at its own nominal -> frequency scale 1 for all, so
    # step time is identical but leaky chips burn more static power
    t = np.asarray(metrics["t_step_s"])
    np.testing.assert_allclose(t, t[0], rtol=1e-6)
    p = np.asarray(metrics["power_w"])
    order_leak = np.argsort(fs.leakage_scale)
    assert p[order_leak[-1]] > p[order_leak[0]]

    # size mismatch is a structured error
    with pytest.raises(ValueError, match="chips"):
        account_step_fleet(PROFILE, PowerPlaneState.fleet(4), fs)


# -- TelemetryLog: fleet-shaped metrics (regression) ---------------------------

def test_telemetry_append_fleet_arrays_no_longer_raises():
    """[n_chips] metrics used to die in float(jax.device_get(...)); now they
    record per-chip vectors + worst/mean/p95 reductions."""
    log = TelemetryLog()
    n = 6
    plane = dataclasses.replace(
        PowerPlaneState.fleet(n),
        v_io=jnp.linspace(0.80, 0.95, n, dtype=jnp.float32))
    metrics = {"power_w": jnp.linspace(100.0, 150.0, n),
               "t_step_s": jnp.full((n,), 2e-3),
               "energy_step_j": jnp.linspace(0.2, 0.3, n),
               "grad_error": jnp.zeros((n,)),
               "fleet/t_fleet_s": jnp.float32(2e-3),
               "scalar_extra": jnp.float32(7.0)}
    rec = log.append_from(3, jnp.float32(1.5), metrics, plane)
    assert rec.n_chips == n
    assert rec.power_w == pytest.approx(125.0)          # fleet mean view
    assert rec.per_chip["power_w"] == pytest.approx(
        list(np.linspace(100.0, 150.0, n)))
    assert rec.fleet["power_w_max"] == pytest.approx(150.0)
    assert rec.fleet["power_w_p95"] == pytest.approx(
        np.percentile(np.linspace(100.0, 150.0, n), 95))
    assert rec.fleet["v_io_min"] == pytest.approx(0.80)  # the gating chip
    assert rec.fleet["t_fleet_s"] == pytest.approx(2e-3)  # in-graph reduction
    assert rec.per_chip["v_io"][0] == pytest.approx(0.80)
    assert rec.extras["scalar_extra"] == pytest.approx(7.0)
    assert log.per_chip_series("power_w").shape == (1, n)
    # totals: per-chip means plus whole-fleet energy
    t = log.totals()
    assert t["energy_j"] == pytest.approx(0.25)
    assert t["fleet_energy_j"] == pytest.approx(0.25 * n)


def test_telemetry_scalar_path_unchanged():
    log = TelemetryLog()
    rec = log.append_from(0, jnp.float32(2.0),
                          {"power_w": jnp.float32(120.0),
                           "t_step_s": jnp.float32(1e-3),
                           "energy_step_j": jnp.float32(0.12),
                           "grad_error": jnp.float32(0.0)},
                          PowerPlaneState.nominal())
    assert rec.n_chips == 1 and rec.per_chip == {} and rec.fleet == {}
    assert rec.power_w == pytest.approx(120.0)
    assert rec.comp_level == 0


# -- fleet trainer -------------------------------------------------------------

def _setup(tmp_path, steps=8, policy=None, fleet_cfg=None, seed=0):
    """Scalar trainer, or fleet trainer when `fleet_cfg` is given."""
    api = registry.build(CFG, remat="none")
    params = api.init(jax.random.PRNGKey(seed))
    opt_cfg = adamw.AdamWConfig(grad_clip_norm=1.0)
    opt = adamw.init_state(params, opt_cfg)
    sched = lambda s: wsd(s, peak_lr=1e-3, warmup_steps=2, stable_steps=50,
                          decay_steps=50)
    step_cfg = StepConfig(microbatches=1, grad_sync="auto", policy=policy)
    if fleet_cfg is None:
        plane, ef = initial_plane_and_ef(params)
        raw = make_train_step(lambda p, b: api.loss_fn(p, b), opt_cfg, sched,
                              PROFILE, step_cfg)
    else:
        plane, ef = initial_plane_and_ef(params, fleet=fleet_cfg.spec)
        raw = make_fleet_train_step(lambda p, b: api.loss_fn(p, b), opt_cfg,
                                    sched, PROFILE, step_cfg, fleet_cfg)
    step = jit_train_step(raw, donate=False)
    data = SyntheticLM(DataConfig(vocab_size=CFG.vocab_size, seq_len=32,
                                  global_batch=4, seed=seed))
    tcfg = TrainerConfig(total_steps=steps, ckpt_every=100,
                         ckpt_dir=str(tmp_path), async_ckpt=False)
    return Trainer(step, data, tcfg,
                   {"params": params, "opt": opt, "plane": plane, "ef": ef})


def test_fleet_step_n1_matches_scalar_trainer(tmp_path):
    """A 1-chip zero-spread fleet step must reproduce the scalar trainer's
    loss/energy trajectory to float32 tolerance (acceptance criterion)."""
    t_scalar = _setup(tmp_path / "s", steps=10, policy=ClosedLoop(), seed=2)
    t_scalar.run()
    fleet_cfg = FleetStepConfig(spec=FleetSpec.uniform(1))
    t_fleet = _setup(tmp_path / "f", steps=10, policy=ClosedLoop(),
                     fleet_cfg=fleet_cfg, seed=2)
    t_fleet.run()
    ls = [r.loss for r in t_scalar.log.records]
    lf = [r.loss for r in t_fleet.log.records]
    np.testing.assert_allclose(lf, ls, rtol=2e-5)
    es = [r.energy_step_j for r in t_scalar.log.records]
    ef = [r.energy_step_j for r in t_fleet.log.records]
    np.testing.assert_allclose(ef, es, rtol=2e-5)
    vs = [r.v_io for r in t_scalar.log.records]
    vf = [r.v_io for r in t_fleet.log.records]
    np.testing.assert_allclose(vf, vs, atol=1e-6)
    assert t_fleet.log.records[-1].n_chips == 1


def test_fleet_trainer_e2e_worst_chip_gates_on_weakest_not_mean(tmp_path):
    """4-chip fleet, one chip 6x more error-sensitive. The weak chip's
    telemetry is over the BER bound while the fleet MEAN is comfortably
    under the escalation threshold — a mean-gated fleet would compress, the
    worst-chip gate must hold everyone at lossless."""
    n, floor, bound = 4, 1e-3, 5e-3
    fs = dataclasses.replace(
        FleetSpec.uniform(n),
        error_sensitivity=np.array([1.0, 1.0, 1.0, 6.0], np.float32))
    mean_err = floor * float(np.mean(fs.error_sensitivity))
    worst_err = floor * 6.0
    assert mean_err < 0.5 * bound < bound < worst_err  # the discriminating regime

    def run_with(policy, sub):
        cfg = FleetStepConfig(spec=fs, link_ber_floor=floor)
        tr = _setup(tmp_path / sub, steps=6, policy=policy, fleet_cfg=cfg)
        tr.run()
        return tr

    gated = run_with(WorstChipGate(BERBounded(error_bound=bound)), "gate")
    rec = gated.log.records[-1]
    assert rec.n_chips == n
    assert len(rec.per_chip["grad_error"]) == n          # per-chip records logged
    assert rec.per_chip["comp_level"] == [0.0] * n       # nobody escalated
    assert rec.fleet["grad_error_worst"] > bound         # the gate had cause

    solo = run_with(BERBounded(error_bound=bound), "solo")
    comp = solo.log.records[-1].per_chip["comp_level"]
    assert comp[3] == 0.0                                # weak chip held back
    assert all(c > 0 for c in comp[:3])                  # strong chips escalated
    # trainer summary surfaces the fleet view
    s = gated.summary()
    assert s["n_chips"] == n and "grad_error_worst" in s["fleet_last"]


def test_fleet_step_stragglers_couple_to_margin(tmp_path):
    """Chips below their nominal VDD_CORE must straggle more often than
    chips at nominal (margin-coupled fault injection)."""
    n = 8
    fs = FleetSpec.uniform(n)
    cfg = FleetStepConfig(spec=fs, straggler_prob=0.15, straggler_factor=4.0,
                          straggler_margin_gain=30.0, seed=3)
    tr = _setup(tmp_path, steps=12, policy=None, fleet_cfg=cfg)
    # undervolt half the fleet's cores
    plane = tr.state["plane"]
    v = np.full((n,), V5E.nominal_v_core, np.float32)
    v[: n // 2] = 0.70
    tr.state["plane"] = dataclasses.replace(plane, v_core=jnp.asarray(v))
    tr.run()
    t = tr.log.per_chip_series("t_chip_s")               # [steps, n]
    straggles = (t > t.min() * 2.0).sum(axis=0)
    assert straggles[: n // 2].sum() > straggles[n // 2:].sum()
    # the synchronous-fleet step time is the max over chips
    last = tr.log.records[-1]
    assert last.fleet["t_fleet_s"] == pytest.approx(
        max(last.per_chip["t_chip_s"]), rel=1e-6)


# -- bus polling back-pressure -------------------------------------------------

def test_polling_backpressure_degrades_interval_keeps_actuations():
    """An oversubscribed segment paces its polls to bus capacity (never a
    backlog), and pending actuations are never dropped."""
    fpm = FleetPowerManager(2)
    fpm.start_polling(interval_s=1e-4)      # << 3 lanes x SW read cost
    fpm.idle(0.2)
    st = fpm.poll_stats[0]
    assert st.polls > 10
    assert st.samples == st.polls * 3
    min_cost = fpm.segments[0].pm.measurement_interval_s() * 3
    assert st.achieved_interval_s >= min_cost * 0.99     # degraded to capacity
    assert st.backpressure > 5.0                         # way over requested
    assert st.deferred >= st.polls - 1
    # actuations still complete mid-polling
    achieved, rep = fpm.apply_setpoints([{2: 0.85}, {2: 0.85}])
    assert rep.ok and rep.lane_writes == 2
    assert achieved[0][2] == pytest.approx(0.85, abs=5e-3)
    assert fpm.stats()["polls_deferred"] >= st.deferred


def test_polling_feasible_interval_holds_and_samples_rails():
    fpm = FleetPowerManager(2)
    fpm.apply_setpoints([{2: 0.90}, {2: 0.80}])
    fpm.start_polling(interval_s=10e-3)
    fpm.idle(0.1)
    for st in fpm.poll_stats.values():
        assert st.deferred == 0
        assert st.achieved_interval_s == pytest.approx(10e-3, rel=1e-6)
        assert st.backpressure == pytest.approx(1.0, rel=1e-3)
    v = fpm.poll_readback(lanes=[2])
    np.testing.assert_allclose(v[:, 0], [0.90, 0.80], atol=5e-3)
    with pytest.raises(RuntimeError, match="already active"):
        fpm.start_polling()
    fpm.stop_polling()
    before = fpm.stats()["polls"]
    fpm.idle(0.05)
    assert fpm.stats()["polls"] == before                # polling stopped


def test_polling_restart_does_not_revive_old_events():
    """stop_polling + start_polling must not leave the first generation's
    periodic events alive (double-rate ghost polling invisible in stats)."""
    fpm = FleetPowerManager(1)
    fpm.start_polling(interval_s=5e-3)
    fpm.idle(0.05)
    fpm.stop_polling()
    fpm.start_polling(interval_s=5e-3)
    fpm.idle(0.1)
    txns = fpm.segments[0].pm.bus.transaction_count
    # reference: one uninterrupted run over the same simulated window
    ref = FleetPowerManager(1)
    ref.start_polling(interval_s=5e-3)
    ref.idle(0.15)
    ref_txns = ref.segments[0].pm.bus.transaction_count
    assert txns <= ref_txns + 12   # ± a couple of polls, not ~1.5x


def test_default_poll_interval_is_table_vi():
    """interval_s=None polls at the configuration's Table VI measurement
    interval x lanes — SW/400kHz: 0.8 ms per lane."""
    fpm = FleetPowerManager(1)
    fpm.start_polling(lanes=[2])
    fpm.idle(0.05)
    st = fpm.poll_stats[0]
    assert st.requested_interval_s == pytest.approx(0.8e-3, abs=0.02e-3)
    assert st.achieved_interval_s == pytest.approx(st.requested_interval_s,
                                                   rel=1e-3)


# -- sharded worst-chip reduction ----------------------------------------------

def test_sharded_fleet_reduce_matches_vmap_path():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 5)) * 3.0
    rmx, rmn, rsm = ops.fleet_reduce(x)
    # guarded fallback: no mesh / single-device mesh -> plain fleet_reduce
    mx, mn, sm = ops.sharded_fleet_reduce(x)
    np.testing.assert_allclose(mx, rmx, rtol=1e-6)
    mesh = jax.make_mesh((1,), ("chips",))
    mx, mn, sm = ops.sharded_fleet_reduce(x, mesh=mesh)
    np.testing.assert_allclose(sm, rsm, rtol=1e-6)
    # forced collective path: pmax/pmin/psum inside shard_map on the mesh
    mx, mn, sm = ops.sharded_fleet_reduce(x, mesh=mesh, use_shard_map=True)
    np.testing.assert_allclose(mx, rmx, rtol=1e-6)
    np.testing.assert_allclose(mn, rmn, rtol=1e-6)
    np.testing.assert_allclose(sm, rsm, rtol=1e-5)
    with pytest.raises(ValueError, match="mesh"):
        ops.sharded_fleet_reduce(x, mesh=None, use_shard_map=True)
    with pytest.raises(ValueError, match="axes"):
        ops.sharded_fleet_reduce(x, mesh=mesh, axis_name="nope",
                                 use_shard_map=True)


# -- fleet_frontier smoke (per-PR fleet regression gate) -----------------------

def test_fleet_frontier_smoke_finite_and_monotone_bus_time():
    from benchmarks import fleet_frontier

    rows = fleet_frontier.run(fleet_sizes=(8, 64), steps=5,
                              host_fleet_sizes=(8,), host_rounds=2)
    by_name = {r["name"]: r for r in rows}
    assert all(math.isfinite(r["us_per_call"]) for r in rows)
    # every policy produced a finite energy at both fleet sizes
    for n in (8, 64):
        for pol in ("static-nominal", "ber-bounded", "closed-loop",
                    "worst-chip[closed-loop]"):
            d = by_name[f"fleet.{n}chips.{pol}"]["derived"]
            e = float(re.search(r"energy=(\S+)J", d).group(1))
            assert math.isfinite(e) and e > 0
    # bus time scales monotonically with fleet size on the serialized
    # (single shared bus) axis while overlapped fleet time stays flat
    ser = {}
    for n in (8, 64):
        d = by_name[f"fleet.{n}chips.bus_actuation"]["derived"]
        ser[n] = float(re.search(r"serialized=(\S+)ms", d).group(1))
    assert ser[64] > ser[8]
    host = by_name["fleet.8chips.host_rollout"]["derived"]
    assert int(re.search(r"polls=(\d+)", host).group(1)) > 0
