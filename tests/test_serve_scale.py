"""Compiled fleet-scale serving tests (docs/serve.md "serving at fleet
scale"): the fused one-dispatch serve tick, vectorized placement, and the
sharded control plane under the router.

  * placement equivalence — `place_batch` is pinned bit-equal to repeated
    sequential `place()` calls on both routers across randomized
    occupancy / headroom / pinned / capacity mixes (including the
    round-robin cursor's final position);
  * router lifecycle — `reset()` rewinds the round-robin cursor at trace
    start, so back-to-back traces on one engine place identically;
  * fused vs loop — the fused `serve_tick` trace is pinned equal to the
    PR-8 per-tick loop on the committed `benchmarks/serve_router.py`
    world: every discrete ledger field (placement times, chips, completion
    times, tokens, defers), the per-reason defer split, degraded chip
    ticks and sheds-by-rail are EXACTLY equal; analog energies agree to
    f32 jit-vs-eager fusion drift (~1e-6 relative);
  * mesh semantics — the shard_map serve path on a FORCED 1-device mesh
    (`shard_control=True`) is bit-equal to the unmeshed engine, the
    PR-7 bit-equality pin; a genuinely multi-device mesh keeps arrival /
    placement-time / token / defer accounting exact and analog state
    allclose (XLA per-shard lane-count codegen drifts the f32 arithmetic
    ~1e-5, the documented PR-7 finding — near-tie chip CHOICES may flip);
  * fast-forward — idle gaps are skipped without accounting or control
    rounds; on a controller-less world the jumped trajectory is
    tick-identical to walking the gap;
  * `summary()` — fleet planes report `fleet_j_per_decoded_token` from
    whole-fleet energy; the historical `j_per_decoded_token` stays
    scalar-plane-only.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.control_plane import InGraphRailController, rail_floors
from repro.core.hwspec import FleetSpec
from repro.core.policy import MultiRailClosedLoop, Policy, RailRequest
from repro.core.power_plane import PowerPlaneState, StepProfile
from repro.core.rails import TPU_V5E_RAIL_MAP
from repro.serve.router import (HeadroomRouter, RoundRobinRouter,
                                headroom_from_packed, rail_headroom)
from repro.serve.traffic import Request, bursty_trace

from benchmarks import serve_router as sr

NDEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    NDEV < 2, reason="needs >= 2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

PROFILE = StepProfile(flops_per_chip=2e12, hbm_bytes_per_chip=8e9,
                      ici_bytes_per_chip=4e9, grad_bytes_per_chip=3e9)


def _req(rid=0, prefill=8, decode=32, t=0.0):
    return Request(rid=rid, t_arrival_s=t, prefill_tokens=prefill,
                   decode_tokens=decode)


_MODEL = {}


def _tiny_engine(**kw):
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve.engine import ServeEngine
    if not _MODEL:
        cfg = get_config("minicpm_2b", tiny=True)
        api = registry.build(cfg)
        _MODEL["cfg"] = cfg
        _MODEL["params"] = api.init(jax.random.PRNGKey(0))
    kw.setdefault("prefill_profile", PROFILE)
    kw.setdefault("decode_profile", PROFILE)
    return ServeEngine(_MODEL["cfg"], _MODEL["params"], max_len=24,
                       batch_size=2, **kw)


# -- place_batch vs sequential place (both routers, randomized mixes) ---------

def _sequential(router, requests, occupancy, headroom, pinned):
    occ = list(np.asarray(occupancy, np.int64))
    out = []
    for r in requests:
        chip = router.place(r, occ, headroom, pinned)
        if chip is None:
            break
        out.append(chip)
        occ[chip] += 1
    return out


def _random_world(rng, n, capacity):
    occ = rng.integers(0, capacity + 1, n)
    headroom = {rail: np.round(rng.uniform(-0.02, 0.3, n), 3)
                for rail in ("VDD_CORE", "VDD_HBM", "VDD_IO")}
    pinned = rng.random(n) < 0.3
    reqs = [Request(rid=i, t_arrival_s=0.0,
                    prefill_tokens=int(rng.integers(1, 64)),
                    decode_tokens=int(rng.integers(1, 128)))
            for i in range(int(rng.integers(1, 3 * n)))]
    return occ, headroom, pinned, reqs


def test_place_batch_matches_sequential_headroom():
    rng = np.random.default_rng(17)
    for trial in range(40):
        n = int(rng.integers(1, 12))
        capacity = int(rng.integers(1, 5))
        occ, headroom, pinned, reqs = _random_world(rng, n, capacity)
        drain = bool(rng.integers(0, 2))
        maybe_pinned = pinned if rng.integers(0, 2) else None
        r_seq = HeadroomRouter(capacity=capacity, drain_pinned=drain)
        r_bat = HeadroomRouter(capacity=capacity, drain_pinned=drain)
        seq = _sequential(r_seq, reqs, occ, headroom, maybe_pinned)
        bat = r_bat.place_batch(reqs, occ, headroom, maybe_pinned)
        assert bat == seq, (trial, n, capacity, drain)


def test_place_batch_matches_sequential_roundrobin_with_cursor():
    rng = np.random.default_rng(29)
    for trial in range(40):
        n = int(rng.integers(1, 12))
        capacity = int(rng.integers(1, 5))
        occ, headroom, pinned, reqs = _random_world(rng, n, capacity)
        cursor = int(rng.integers(0, n))
        r_seq = RoundRobinRouter(capacity=capacity, _cursor=cursor)
        r_bat = RoundRobinRouter(capacity=capacity, _cursor=cursor)
        seq = _sequential(r_seq, reqs, occ, headroom, pinned)
        bat = r_bat.place_batch(reqs, occ, headroom, pinned)
        assert bat == seq, (trial, n, capacity, cursor)
        # the cursor the NEXT trace tick starts from must agree too
        assert r_bat._cursor == r_seq._cursor, (trial, n, capacity, cursor)


def test_place_batch_empty_and_no_eligible():
    hr = HeadroomRouter(capacity=2)
    rr = RoundRobinRouter(capacity=2)
    headroom = {"VDD_HBM": np.array([0.1, 0.2]),
                "VDD_CORE": np.array([0.1, 0.2])}
    assert hr.place_batch([], [0, 0], headroom) == []
    assert rr.place_batch([], [0, 0], headroom) == []
    # every chip full: nothing places, the cursor does not move
    assert hr.place_batch([_req()], [2, 2], headroom) == []
    assert rr.place_batch([_req()], [2, 2], headroom) == []
    assert rr._cursor == 0
    # every chip pinned: the headroom router drains, round-robin is blind
    pinned = np.array([True, True])
    assert hr.place_batch([_req()], [0, 0], headroom, pinned) == []
    assert rr.place_batch([_req()], [0, 0], headroom, pinned) == [0]


def test_round_robin_reset_called_at_trace_start():
    """serve_trace resets the router, so a dirty cursor (left by a prior
    trace) cannot shift the next trace's placements."""
    fs = FleetSpec.sample(3, seed=9)
    trace = bursty_trace(6, seed=8)

    def first_chip(cursor):
        eng = _tiny_engine(policy=MultiRailClosedLoop(), fleet=fs,
                           router=RoundRobinRouter(capacity=2))
        eng.router._cursor = cursor
        led = eng.serve_trace(trace, max_ticks=400)
        return led.records()[0].chip

    assert first_chip(0) == first_chip(2)


# -- packed headroom rows ------------------------------------------------------

def test_headroom_from_packed_matches_rail_headroom():
    plane = PowerPlaneState.fleet(4)
    held = jnp.stack([jnp.broadcast_to(jnp.asarray(getattr(plane, f),
                                                   jnp.float32), (4,))
                      for f in ("v_core", "v_hbm", "v_io")])
    rows = np.asarray(held - rail_floors(plane, None, TPU_V5E_RAIL_MAP))
    unpacked = headroom_from_packed(rows)
    direct = rail_headroom(plane, None)
    assert set(unpacked) == set(direct)
    for rail in direct:
        np.testing.assert_allclose(unpacked[rail], direct[rail], atol=1e-7)


# -- fused serve_tick vs the PR-8 loop (the committed bench world) ------------

def _bench_world_engine(router, n_chips=8, mesh=None, shard_control=None,
                        **kw):
    """The committed benchmarks/serve_router.py world at test scale: same
    fleet seed, same SOR-learning envelope-blind controller, same
    load-coupled frontier observables. Extra kwargs (batch_cap,
    decode_profile, ...) pass through to the engine —
    tests/test_serve_batching.py builds the continuous-batching variants
    of the same world."""
    fs = FleetSpec.sample(n_chips, seed=sr.SEED)
    ctrl = InGraphRailController(
        sr._EnvelopeBlindWalk(floors=dict(sr.POLICY_FLOORS), backoff=1.01,
                              name="envelope-blind-walk"),
        sor=sr.SOR_CFG)
    eng = _tiny_engine(fleet=fs, controller=ctrl, router=router,
                       mesh=mesh, shard_control=shard_control, **kw)
    return eng, sr._make_observe(fs, n_chips)


def _discrete(eng, ledger):
    """Every discrete quantity of a traced run — the fields the fused path
    pins EXACTLY equal to the loop path (times are tick-grid multiples
    accumulated identically in float64 on both paths)."""
    return {
        "records": [(r.rid, r.t_placed_s, r.chip, r.t_done_s, r.tokens_out,
                     r.defers, r.defer_time_s) for r in ledger.records()],
        "defers_by_reason": dict(ledger.defers_by_reason),
        "ticks": eng.last_trace["ticks"],
        "max_occupancy": eng.last_trace["max_occupancy"],
        "degraded_chip_ticks": eng.last_trace["degraded_chip_ticks"],
        "unplaced": eng.last_trace["unplaced"],
        "unfinished": eng.last_trace["unfinished"],
        "decode_sheds": eng.stats.decode_sheds,
        "sheds_by_rail": dict(eng.stats.sheds_by_rail),
        "sheds_by_reason": dict(eng.stats.sheds_by_reason),
        "prefill_tokens": eng.stats.prefill_tokens,
        "decode_tokens": eng.stats.decode_tokens,
    }


def _assert_analog_close(led_a, led_b, eng_a, eng_b, rtol):
    assert led_a.fleet_energy_j == pytest.approx(led_b.fleet_energy_j,
                                                 rel=rtol)
    for ra, rb in zip(led_a.records(), led_b.records()):
        assert ra.energy_j == pytest.approx(rb.energy_j, rel=rtol, abs=1e-9)
    assert eng_a.stats.fleet_energy_j == pytest.approx(
        eng_b.stats.fleet_energy_j, rel=rtol)
    for field in ("v_core", "v_hbm", "v_io"):
        np.testing.assert_allclose(
            np.asarray(jax.device_get(getattr(eng_a.plane, field))),
            np.asarray(jax.device_get(getattr(eng_b.plane, field))),
            rtol=rtol, err_msg=field)


@pytest.mark.parametrize("make_router", [
    lambda: HeadroomRouter(capacity=3),
    lambda: RoundRobinRouter(capacity=3),
], ids=["headroom", "roundrobin"])
def test_fused_trace_matches_loop_trace(make_router):
    trace = bursty_trace(24, seed=sr.SEED, quiet_rate_hz=8.0,
                         burst_rate_hz=40.0, decode_mean=48.0)
    runs = {}
    for fused in (True, False):
        eng, observe = _bench_world_engine(make_router())
        led = eng.serve_trace(trace, observe=observe, max_ticks=900,
                              error_bound=sr.ERROR_BOUND, fused=fused)
        runs[fused] = (eng, led)
    eng_f, led_f = runs[True]
    eng_l, led_l = runs[False]
    assert eng_f.last_trace["fused"] and not eng_l.last_trace["fused"]
    assert _discrete(eng_f, led_f) == _discrete(eng_l, led_l)
    assert led_f.summary()["completed"] == 24
    # analog state: one fused program vs eager per-op dispatch reassociates
    # f32 FMAs — equality is to fusion drift, not bitwise
    _assert_analog_close(led_f, led_l, eng_f, eng_l, rtol=1e-5)


class _PinHbmPolicy(Policy):
    """Requests an impossible VDD_HBM so arbitration pins every chip at the
    HBM floor — deterministic pinned-drain sheds on both tick paths."""
    name = "pin-hbm-floor"

    def decide(self, state, frame):
        return RailRequest(v_hbm=jnp.zeros_like(
            jnp.asarray(state.v_hbm, jnp.float32)),
            reason="pinned-at-floor")


def test_fused_loop_pinned_drain_sheds_by_rail_equal():
    """A world that actually sheds: every chip pinned at the VDD_HBM floor
    makes the headroom router drain — both paths must report the SAME
    nonzero sheds_by_rail / defers_by_reason split."""
    fs = FleetSpec.sample(3, seed=9)
    trace = bursty_trace(4, seed=2)
    runs = {}
    for fused in (True, False):
        eng = _tiny_engine(policy=_PinHbmPolicy(), fleet=fs,
                           router=HeadroomRouter(capacity=2))
        led = eng.serve_trace(trace, max_ticks=40, fused=fused)
        runs[fused] = (eng, led)
    eng_f, led_f = runs[True]
    eng_l, led_l = runs[False]
    assert eng_f.stats.sheds_by_rail.get("VDD_HBM", 0) > 0
    assert led_f.defers_by_reason.get("pinned-drain", 0) > 0
    assert _discrete(eng_f, led_f) == _discrete(eng_l, led_l)


def test_fused_requires_in_graph_controller():
    from repro.core.control_plane import HostRailController
    fs = FleetSpec.sample(2, seed=5)
    eng = _tiny_engine(controller=HostRailController(MultiRailClosedLoop(),
                                                     n_chips=2),
                       fleet=fs, router=HeadroomRouter(capacity=2))
    # auto-resolution falls back to the loop path for host controllers
    led = eng.serve_trace(bursty_trace(3, seed=2), max_ticks=200)
    assert eng.last_trace["fused"] is False
    assert led.summary()["completed"] == 3
    with pytest.raises(ValueError, match="fused=False"):
        eng.serve_trace(bursty_trace(3, seed=2), max_ticks=10, fused=True)


# -- fast-forward --------------------------------------------------------------

def test_fast_forward_skips_idle_gaps_tick_identically():
    """Controller-less world (static plane): jumping an idle gap must land
    on the same tick grid the walked run reaches — identical placements,
    completions and per-request energies; only accounted tick count (and
    hence fleet energy) differs by exactly the skipped idle ticks."""
    fs = FleetSpec.sample(2, seed=5)
    trace = [_req(rid=0, t=0.0, prefill=4, decode=8),
             _req(rid=1, t=5.0, prefill=4, decode=8)]
    runs = {}
    # binary-exact tick (2^-6 s): the walked run's accumulated grid and the
    # jumped run's one-multiply grid are the SAME float64s, so the
    # equality below is exact, not approximate
    for ff in (False, True):
        eng = _tiny_engine(fleet=fs, router=HeadroomRouter(capacity=2))
        led = eng.serve_trace(list(trace), max_ticks=6000, tick_s=1 / 64,
                              fast_forward=ff)
        runs[ff] = (eng, led)
    eng_w, led_w = runs[False]
    eng_f, led_f = runs[True]
    assert eng_w.last_trace["fast_forward_ticks"] == 0
    ff_ticks = eng_f.last_trace["fast_forward_ticks"]
    assert ff_ticks > 0
    # skipped ticks are exactly the walked run's extra accounted ticks
    assert (eng_f.last_trace["ticks"] + ff_ticks
            == eng_w.last_trace["ticks"])
    assert [(r.rid, r.t_placed_s, r.chip, r.t_done_s, r.tokens_out)
            for r in led_f.records()] == \
           [(r.rid, r.t_placed_s, r.chip, r.t_done_s, r.tokens_out)
            for r in led_w.records()]
    for rf, rw in zip(led_f.records(), led_w.records()):
        assert rf.energy_j == pytest.approx(rw.energy_j, rel=1e-6)
    # the skipped ticks ran no accounting: strictly less fleet energy
    assert led_f.fleet_energy_j < led_w.fleet_energy_j


def test_fast_forward_requires_fused_path():
    fs = FleetSpec.sample(2, seed=5)
    eng = _tiny_engine(policy=MultiRailClosedLoop(), fleet=fs,
                       router=HeadroomRouter(capacity=2))
    with pytest.raises(ValueError, match="fast_forward"):
        eng.serve_trace(bursty_trace(3, seed=2), max_ticks=10,
                        fused=False, fast_forward=True)


# -- mesh semantics ------------------------------------------------------------

def _mesh(ndev):
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:ndev]), ("chips",))


def _traced(eng, observe, n_requests=16, max_ticks=600):
    trace = bursty_trace(n_requests, seed=sr.SEED, quiet_rate_hz=8.0,
                         burst_rate_hz=40.0, decode_mean=48.0)
    return eng.serve_trace(trace, observe=observe, max_ticks=max_ticks,
                           error_bound=sr.ERROR_BOUND)


def test_mesh_single_device_fallback_bit_equal():
    """shard_control=True on a 1-device mesh forces the shard_map serve
    path on identical global shapes — the PR-7 bit-equality pin, extended
    to the whole traced serve run (discrete ledger AND analog state)."""
    eng0, obs0 = _bench_world_engine(HeadroomRouter(capacity=3))
    led0 = _traced(eng0, obs0)
    eng1, obs1 = _bench_world_engine(HeadroomRouter(capacity=3),
                                     mesh=_mesh(1), shard_control=True)
    assert eng1.shard_control and eng1._sharded_round is not None
    led1 = _traced(eng1, obs1)
    assert _discrete(eng0, led0) == _discrete(eng1, led1)
    assert led0.fleet_energy_j == led1.fleet_energy_j
    for field in ("v_core", "v_hbm", "v_io", "energy_j"):
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(getattr(eng0.plane, field))),
            np.asarray(jax.device_get(getattr(eng1.plane, field))),
            err_msg=field)


@multi_device
def test_mesh_multi_device_serve_matches_unmeshed():
    """A genuinely sharded serve trace against the unmeshed engine. XLA
    codegen on per-shard lane counts drifts f32 arithmetic ~1e-5 (the
    PR-7 finding), so near-tie CHIP CHOICES may flip; what must hold
    exactly is the arrival/placement-time grid, token accounting and the
    defer ledger, with analog state allclose."""
    ndev = max(d for d in (2, 4, 8) if d <= NDEV)
    n_chips = 2 * ndev
    eng0, obs0 = _bench_world_engine(HeadroomRouter(capacity=3),
                                     n_chips=n_chips)
    led0 = _traced(eng0, obs0)
    eng8, obs8 = _bench_world_engine(HeadroomRouter(capacity=3),
                                     n_chips=n_chips, mesh=_mesh(ndev))
    assert eng8.shard_control
    led8 = _traced(eng8, obs8)
    a, b = _discrete(eng0, led0), _discrete(eng8, led8)
    assert [(r[0], r[1], r[4], r[5]) for r in a["records"]] == \
           [(r[0], r[1], r[4], r[5]) for r in b["records"]]  # rid/placed/tok/defers
    for key in ("defers_by_reason", "unplaced", "unfinished",
                "prefill_tokens", "decode_tokens"):
        assert a[key] == b[key], key
    assert led0.summary()["completed"] == led8.summary()["completed"] == 16
    _assert_analog_close(led0, led8, eng0, eng8, rtol=1e-3)


def test_mesh_validation_errors():
    fs = FleetSpec.sample(4, seed=sr.SEED)
    with pytest.raises(ValueError, match="needs a mesh"):
        _tiny_engine(fleet=fs, router=HeadroomRouter(capacity=2),
                     shard_control=True)
    with pytest.raises(ValueError, match="fleet"):
        _tiny_engine(mesh=_mesh(1), shard_control=True)
    # shard_map shards the learned round: a plain walking policy (no SOR)
    # has no in-graph round to shard
    with pytest.raises(ValueError, match="sor"):
        _tiny_engine(policy=MultiRailClosedLoop(), fleet=fs,
                     router=HeadroomRouter(capacity=2), mesh=_mesh(1),
                     shard_control=True)


# -- summary() fleet energy fields --------------------------------------------

def test_summary_fleet_j_per_decoded_token():
    eng, observe = _bench_world_engine(HeadroomRouter(capacity=3),
                                       n_chips=4)
    _traced(eng, observe, n_requests=6, max_ticks=400)
    s = eng.summary()
    assert "j_per_decoded_token" not in s      # scalar-plane-only now
    assert s["fleet_j_per_decoded_token"] == pytest.approx(
        eng.stats.fleet_energy_j / max(eng.stats.decode_tokens, 1))
    # the historical bug: per-chip MEAN energy over fleet-total tokens
    # understated the fleet's cost by 1/n_chips
    assert s["fleet_j_per_decoded_token"] == pytest.approx(
        s["energy_j"] / max(eng.stats.decode_tokens, 1) * eng.n_chips)
    for key in ("v_core_min", "v_io_min", "comp_level_min"):
        assert key in s


def test_summary_scalar_plane_keeps_scalar_field():
    eng = _tiny_engine(policy=MultiRailClosedLoop())
    eng.generate(np.zeros((2, 4), np.int32), max_new_tokens=3)
    s = eng.summary()
    assert "fleet_j_per_decoded_token" not in s
    assert s["j_per_decoded_token"] == pytest.approx(
        eng.stats.energy_j / max(eng.stats.decode_tokens, 1))
